"""Model assembly: embeddings, superblock scan stacks, decode, enc-dec.

The layer stack is a single ``lax.scan`` over ``cfg.n_rep`` superblocks
(each superblock = ``cfg.pattern``, a short heterogeneous list of sublayers)
with ``jax.checkpoint`` on the body — so HLO size is O(pattern), not
O(n_layers), which keeps the 512-device dry-run compile tractable and is
the standard remat policy for training memory.

Params layout:
  params = {
    'embed': (V, D), 'unembed': (D, V), 'final_norm': {...},
    'blocks': pytree stacked over n_rep,       # decoder / main stack
    'enc_blocks': ..., 'enc_norm': {...},      # encoder-decoder only
  }
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import layers, sharding
from .arch import ArchConfig, LayerSpec
from .sharding import constrain


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_sublayer(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 6)
    p: dict = {}
    if spec.mixer == "attn":
        p["mixer_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["mixer"] = layers.init_attention(ks[0], cfg, dtype)
    else:
        p["mixer_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["mixer"] = layers.init_mamba(ks[0], cfg, dtype)
    if spec.cross_attn:
        p["cross_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = layers.init_attention(ks[1], cfg, dtype)
    if spec.ff == "mlp":
        p["ff_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["ff"] = layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif spec.ff == "moe":
        p["ff_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["ff"] = layers.init_moe(ks[2], cfg, dtype)
    return p


def _init_block(key, cfg: ArchConfig, pattern, dtype) -> dict:
    ks = jax.random.split(key, len(pattern))
    return {f"l{i}": _init_sublayer(ks[i], cfg, spec, dtype)
            for i, spec in enumerate(pattern)}


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    k_emb, k_unemb, k_blocks, k_enc = jax.random.split(key, 4)
    d = cfg.d_model
    params: dict = {
        "embed": (jax.random.normal(k_emb, (cfg.padded_vocab, d)) * 0.02).astype(dtype),
        "final_norm": layers.init_rmsnorm(d, dtype),
        "blocks": jax.vmap(
            lambda k: _init_block(k, cfg, cfg.pattern, dtype)
        )(jax.random.split(k_blocks, cfg.n_rep)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(k_unemb, (d, cfg.padded_vocab)) / np.sqrt(d)
        ).astype(dtype)
    if cfg.is_encoder_decoder:
        n_enc_rep = cfg.encoder_layers // len(cfg.encoder_pattern)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_block(k, cfg, cfg.encoder_pattern, dtype)
        )(jax.random.split(k_enc, n_enc_rep))
        params["enc_norm"] = layers.init_rmsnorm(d, dtype)
    return params


def param_count(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params)))


def active_param_count(cfg: ArchConfig, params) -> int:
    """Params touched per token (MoE: top_k of routed experts)."""
    total = param_count(params)
    if not cfg.moe_experts:
        return total
    inactive = 0
    for pat_idx, spec in enumerate(cfg.pattern):
        if spec.ff != "moe":
            continue
        blk = params["blocks"][f"l{pat_idx}"]["ff"]
        for name in ("exp_wgate", "exp_wi", "exp_w_down"):
            per_expert = np.prod(blk[name].shape) // cfg.padded_experts
            inactive += (cfg.padded_experts - cfg.moe_top_k) * per_expert
    return total - int(inactive)


# ---------------------------------------------------------------------------
# Forward (full sequence: training / prefill)
# ---------------------------------------------------------------------------

def _apply_sublayer(p, x, *, cfg, spec: LayerSpec, window, memory, positions,
                    collect: bool = False):
    cache = {}
    h = layers.rmsnorm(p["mixer_norm"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        h = layers.attention(
            p["mixer"], h, cfg,
            causal=spec.causal, window=window, positions=positions,
            return_kv=collect,
        )
        if collect:
            h, (k, v) = h
            cache = {"k": k, "v": v}
    else:
        h = layers.mamba(p["mixer"], h, cfg, return_cache=collect)
        if collect:
            h, cache = h
    x = x + h
    if spec.cross_attn and memory is not None:
        h = layers.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        h = layers.attention(p["cross"], h, cfg, memory=memory)
        x = x + h
    aux = jnp.zeros((), jnp.float32)
    if spec.ff == "mlp":
        h = layers.rmsnorm(p["ff_norm"], x, cfg.norm_eps)
        x = x + layers.mlp(p["ff"], h)
    elif spec.ff == "moe":
        h = layers.rmsnorm(p["ff_norm"], x, cfg.norm_eps)
        out, aux = layers.moe(p["ff"], h, cfg)
        x = x + out
    if collect:
        return x, aux, cache
    return x, aux


def _run_stack(blocks, x, cfg, pattern, *, window=0, memory=None, positions=None,
               collect: bool = False):
    # remat at BOTH levels: each sublayer is checkpointed so the backward
    # of a superblock re-materializes one sublayer at a time (jamba's
    # 8-sublayer block would otherwise hold every mamba/MoE intermediate
    # alive simultaneously), and the scan body is checkpointed so only the
    # n_rep block boundaries are saved.
    def body(carry, block_p):
        x, aux = carry
        # re-assert the FSDP/TP sharding on the block params INSIDE the
        # scan body: the transpose of a sharding constraint constrains the
        # COTANGENT, so per-layer param grads come out reduce-scattered
        # over `data` instead of all-reduced to replicated slices
        # (335 GiB/step -> ~20 GiB at granite-8b scale, §Perf iter 1b).
        block_p = sharding.constrain_tree(block_p, fsdp=True)
        caches = {}
        for i, spec in enumerate(pattern):
            sub = functools.partial(
                _apply_sublayer, cfg=cfg, spec=spec,
                window=window, memory=memory, positions=positions,
                collect=collect,
            )
            if len(pattern) > 1:
                # inner remat only pays off for heterogeneous superblocks
                # (jamba's 8 sublayers); for single-sublayer blocks it
                # nests inside the body checkpoint and doubles the
                # recomputed forward (§Perf iter 1c: -25% dot FLOPs).
                sub = jax.checkpoint(
                    sub, policy=jax.checkpoint_policies.nothing_saveable)
            out = sub(block_p[f"l{i}"], x)
            if collect:
                x, a, caches[f"l{i}"] = out
            else:
                x, a = out
            aux = aux + a
        x = constrain(x, "batch", None, None)
        return (x, aux), caches

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    if collect:
        return x, aux, caches
    return x, aux


def encode(cfg: ArchConfig, params, enc_embeds: jax.Array) -> jax.Array:
    """Encoder stack over modality frame embeddings (B, Sm, D)."""
    x = constrain(enc_embeds, "batch", None, None)
    x, _ = _run_stack(params["enc_blocks"], x, cfg, cfg.encoder_pattern)
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,                       # (B, S_text)
    *,
    modal_embeds: Optional[jax.Array] = None,  # (B, P, D) vision/audio stub
    enc_embeds: Optional[jax.Array] = None,    # (B, Sm, D) enc-dec source
    window: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Returns (hidden (B, S_total, D), moe_aux)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if modal_embeds is not None:
        x = jnp.concatenate([modal_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", None, None)
    memory = None
    if cfg.is_encoder_decoder:
        assert enc_embeds is not None
        memory = encode(cfg, params, enc_embeds)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    x, aux = _run_stack(
        params["blocks"], x, cfg, cfg.pattern,
        window=window, memory=memory, positions=positions,
    )
    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


_VOCAB_PAD_NEG = -1e30


def _mask_pad_logits(cfg: ArchConfig, logits: jax.Array) -> jax.Array:
    """Force vocab-padding logits to -inf so softmax/argmax never see them."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    neg = jnp.asarray(_VOCAB_PAD_NEG, logits.dtype)
    col = jnp.arange(cfg.padded_vocab) >= cfg.vocab
    return jnp.where(col, neg, logits)


def logits_fn(cfg: ArchConfig, params, hidden: jax.Array) -> jax.Array:
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = constrain(hidden @ unemb, "batch", None, "model")
    return _mask_pad_logits(cfg, logits)


def lm_loss(
    cfg: ArchConfig,
    params,
    hidden: jax.Array,        # (B, S, D)
    targets: jax.Array,       # (B, S) int32
    mask: Optional[jax.Array] = None,
    chunk: int = 512,
) -> jax.Array:
    """Chunked softmax cross-entropy — never materializes (B, S, V) in f32."""
    b, s, d = hidden.shape
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    # un-FSDP the unembedding BEFORE the matmul: contracting-dim (d) sharding
    # would make XLA all-reduce the full (B,c,V) f32 product (2 GiB/device at
    # jamba scale); gathering the (d, V/16) weight shard is ~64 MB.
    unemb = constrain(unemb, None, "model")
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    c = layers.largest_divisor(s, chunk)
    nc = s // c

    def chunk_loss(args):
        h, t, m = args  # (B, c, D), (B, c), (B, c)
        logits = (h @ unemb).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "model")
        logits = _mask_pad_logits(cfg, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # correct-class logit via masked reduction, NOT take_along_axis:
        # a gather over the vocab-sharded dim makes GSPMD replicate the
        # whole (B, c, V) f32 logits per device (2 GiB at jamba scale);
        # the elementwise mask + sum partitions cleanly (local + psum).
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2) == t[..., None]
        )
        correct = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return jnp.sum((lse - correct) * m), jnp.sum(m)

    chunk_loss = jax.checkpoint(chunk_loss)
    xs = (
        jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0),
        jnp.moveaxis(targets.reshape(b, nc, c), 1, 0),
        jnp.moveaxis(mask.reshape(b, nc, c), 1, 0),
    )
    losses, counts = jax.lax.map(chunk_loss, xs)
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)


# ---------------------------------------------------------------------------
# Decode (one token against caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype, *, window: int = 0,
               memory_len: int = 0) -> dict:
    """Per-superblock caches, stacked over n_rep (leading axis)."""
    sbuf = min(max_len, window) if window else max_len
    c = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.mixer == "attn":
            c[f"l{i}"] = layers.init_kv_cache(
                batch, sbuf, cfg.n_kv_heads, cfg.hd, dtype
            )
        else:
            c[f"l{i}"] = layers.init_mamba_cache(batch, cfg, dtype)
        if spec.cross_attn and memory_len:
            c[f"l{i}_xk"] = jnp.zeros(
                (batch, memory_len, cfg.n_kv_heads, cfg.hd), dtype
            )
            c[f"l{i}_xv"] = jnp.zeros_like(c[f"l{i}_xk"])
    # stack over superblocks (leading n_rep axis, matching params['blocks'])
    return jax.tree.map(
        lambda l: jnp.zeros((cfg.n_rep,) + l.shape, l.dtype), c
    )


def prefill_cross_cache(cfg: ArchConfig, params, cache, memory: jax.Array):
    """Precompute cross-attention K/V from encoder memory into the cache."""
    b, sm, _ = memory.shape

    def per_block(block_p, block_c):
        block_c = dict(block_c)
        for i, spec in enumerate(cfg.pattern):
            if spec.cross_attn:
                p = block_p[f"l{i}"]["cross"]
                block_c[f"l{i}_xk"] = (memory @ p["wk"]).reshape(
                    b, sm, cfg.n_kv_heads, cfg.hd
                ).astype(block_c[f"l{i}_xk"].dtype)
                block_c[f"l{i}_xv"] = (memory @ p["wv"]).reshape(
                    b, sm, cfg.n_kv_heads, cfg.hd
                ).astype(block_c[f"l{i}_xv"].dtype)
        return block_c

    return jax.vmap(per_block)(params["blocks"], cache)


def decode_step(
    cfg: ArchConfig,
    params,
    cache,
    token: jax.Array,    # (B, 1) int32
    pos: jax.Array,      # scalar int32
    *,
    window: int = 0,
) -> tuple[jax.Array, dict]:
    """One serve step: returns (logits (B, 1, V), new cache)."""
    x = jnp.take(params["embed"], token, axis=0)  # (B, 1, D)
    x = constrain(x, "batch", None, None)
    pos = jnp.asarray(pos, jnp.int32)

    def body(x, inp):
        block_p, block_c = inp
        new_c = dict(block_c)
        for i, spec in enumerate(cfg.pattern):
            p = block_p[f"l{i}"]
            h = layers.rmsnorm(p["mixer_norm"], x, cfg.norm_eps)
            if spec.mixer == "attn":
                h, new_c[f"l{i}"] = layers.attention_decode(
                    p["mixer"], h, block_c[f"l{i}"], pos, cfg, window=window
                )
            else:
                h, new_c[f"l{i}"] = layers.mamba_decode(
                    p["mixer"], h, block_c[f"l{i}"], cfg
                )
            x = x + h
            if spec.cross_attn and f"l{i}_xk" in block_c:
                h = layers.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
                h, _ = layers.attention_decode(
                    p["cross"], h, block_c[f"l{i}"], pos, cfg,
                    memory_kv=(block_c[f"l{i}_xk"], block_c[f"l{i}_xv"]),
                )
                x = x + h
            if spec.ff == "mlp":
                h = layers.rmsnorm(p["ff_norm"], x, cfg.norm_eps)
                x = x + layers.mlp(p["ff"], h)
            elif spec.ff == "moe":
                h = layers.rmsnorm(p["ff_norm"], x, cfg.norm_eps)
                out, _ = layers.moe(p["ff"], h, cfg)
                x = x + out
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# Prefill (full-sequence forward that also produces the decode cache)
# ---------------------------------------------------------------------------

def prefill(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,                         # (B, S_text)
    *,
    modal_embeds: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
    window: int = 0,
    max_len: int = 0,
):
    """Run the full sequence, returning (last_logits (B,1,V), cache, aux).

    The cache layout matches :func:`init_cache` (leading n_rep axis) so
    ``decode_step`` continues from position S. Attention caches hold the
    post-rope K/V of the whole prefix; mamba caches hold the final SSM
    state + conv tail. Windowed prefill requires S <= window (the serve
    driver chunks longer prefixes through decode).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    if modal_embeds is not None:
        x = jnp.concatenate([modal_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", None, None)
    memory = None
    if cfg.is_encoder_decoder:
        assert enc_embeds is not None
        memory = encode(cfg, params, enc_embeds)
    s = x.shape[1]
    if window:
        assert s <= window, "windowed prefill longer than the window"
    positions = jnp.arange(s)[None, :]
    x, aux, cache = _run_stack(
        params["blocks"], x, cfg, cfg.pattern,
        window=window, memory=memory, positions=positions, collect=True,
    )
    if max_len and max_len > s and not window:
        # pad attention K/V buffers so decode can append after position S
        def pad_kv(block_c):
            block_c = dict(block_c)
            for i, spec in enumerate(cfg.pattern):
                if spec.mixer == "attn":
                    c = dict(block_c[f"l{i}"])
                    pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
                    c["k"] = jnp.pad(c["k"], pad)
                    c["v"] = jnp.pad(c["v"], pad)
                    block_c[f"l{i}"] = c
            return block_c

        cache = pad_kv(cache)
    if cfg.is_encoder_decoder:
        cache = prefill_cross_cache_from(cfg, params, cache, memory)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(cfg, params, x[:, -1:, :])
    return logits, cache, aux


def prefill_cross_cache_from(cfg: ArchConfig, params, cache, memory: jax.Array):
    """Attach cross-attention K/V (computed from encoder memory) to a
    prefill-collected cache (adds the ``l{i}_xk/xv`` entries)."""
    b, sm, _ = memory.shape

    def per_block(block_p, block_c):
        block_c = dict(block_c)
        for i, spec in enumerate(cfg.pattern):
            if spec.cross_attn:
                p = block_p[f"l{i}"]["cross"]
                block_c[f"l{i}_xk"] = (memory @ p["wk"]).reshape(
                    b, sm, cfg.n_kv_heads, cfg.hd
                )
                block_c[f"l{i}_xv"] = (memory @ p["wv"]).reshape(
                    b, sm, cfg.n_kv_heads, cfg.hd
                )
        return block_c

    return jax.vmap(per_block)(params["blocks"], cache)
