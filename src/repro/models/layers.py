"""Functional building blocks: norm, rope, attention, MLP, MoE, Mamba2-SSD.

All modules are (init, apply) pairs of pure functions over dict pytrees.
dtype policy: params in ``param_dtype`` (bf16 for big configs), math in f32
where it matters (softmax, SSM scan, router), outputs cast back.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .sharding import constrain, get_mesh

Init = jax.nn.initializers

# ---------------------------------------------------------------------------
# Pallas attention integration. Auto-on for TPU (native lowering), off for
# CPU (interpret mode is Python-slow and pallas_call does not partition
# under GSPMD without a shard_map wrapper — single-device / explicitly
# enabled only; tests force it on with interpret=True to exercise the
# integrated path end to end).
# ---------------------------------------------------------------------------

_PALLAS_ATTN: bool | None = None  # None = auto (TPU yes, CPU no)


def set_pallas_attention(on) -> None:
    global _PALLAS_ATTN
    _PALLAS_ATTN = on


def _use_pallas_attention() -> bool:
    if get_mesh() is not None:
        return False
    if _PALLAS_ATTN is None:
        return jax.default_backend() == "tpu"
    return bool(_PALLAS_ATTN)


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (for chunked loops over
    sequences whose length need not be a power of two, e.g. VLM concats)."""
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return 1


def _dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf * rms).astype(x.dtype) * scale


def _rmsnorm_fwd(scale, x, eps):
    return _rmsnorm_core(scale, x, eps), (scale, x)


def _rmsnorm_bwd(eps, res, dy):
    # Explicit VJP with f32 confined to THIS op: the autodiff rule would
    # thread f32 (B,S,D) cotangents into the surrounding graph, and the TP
    # dx all-reduce then runs at 4 bytes/elt instead of 2 (§Perf).
    scale, x = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    xhat = xf * rms
    dscale = jnp.sum(dyf * xhat, axis=tuple(range(x.ndim - 1)))
    g = dyf * sf
    dx = rms * (g - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))
    return dscale.astype(scale.dtype), dx.astype(x.dtype)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    return _rmsnorm_core(p["scale"], x, eps)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh), positions: (..., S) broadcastable int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / non-causal / cross)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": _dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }


def _flash_attn(
    q: jax.Array,  # (B, Sq, Hkv, G, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool,
    window: int,
    q_offset: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jax.Array:
    """Chunked flash-style attention in pure JAX (online softmax over kv
    chunks, scan over q chunks). Peak memory O(q_chunk * k_chunk) per head
    instead of O(Sq * Skv) — required to even *lower* the 32k shapes.
    """
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    qc = largest_divisor(sq, q_chunk)
    kc = largest_divisor(skv, k_chunk)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / np.sqrt(dh)
    q = q.reshape(b, nq, qc, hkv, g, dh)

    def q_chunk_fn(qi, q_blk):
        # q_blk: (B, qc, Hkv, G, Dh)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            k_pos = ki * kc + jnp.arange(kc)
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, g, qc), -1e30, jnp.float32),
            jnp.zeros((b, hkv, g, qc), jnp.float32),
            jnp.zeros((b, hkv, g, qc, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4))  # (B, qc, Hkv, G, Dh)

    body = jax.checkpoint(q_chunk_fn, policy=jax.checkpoint_policies.nothing_saveable)
    outs = jax.lax.map(lambda args: body(*args), (jnp.arange(nq), jnp.moveaxis(q, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, dh)


def attention(
    p: dict,
    x: jax.Array,                      # (B, S, D)
    cfg,
    *,
    causal: bool = True,
    window: int = 0,
    positions: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,  # cross-attention memory (B, Sm, D)
    return_kv: bool = False,
):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // hkv
    src = memory if memory is not None else x
    sm = src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (src @ p["wk"]).reshape(b, sm, hkv, hd)
    v = (src @ p["wv"]).reshape(b, sm, hkv, hd)
    q = constrain(q, "batch", None, "model", None)
    # k/v: no head-axis constraint — hkv (8) rarely divides the TP axis (16);
    # propagation from the column-sharded wk/wv picks an (hkv x hd) tiling.
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    if memory is None:  # self-attention: rope
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if _use_pallas_attention():
        from repro.kernels.flash_prefill import flash_prefill

        out = flash_prefill(
            q, k, v,
            causal=causal and memory is None,
            window=window if memory is None else 0,
            interpret=jax.default_backend() != "tpu",
        ).reshape(b, s, hkv, g, hd)
    else:
        qg = q.reshape(b, s, hkv, g, hd)
        out = _flash_attn(
            qg, k, v,
            causal=causal and memory is None,
            window=window if memory is None else 0,
        )
    out = out.reshape(b, s, h * hd).astype(x.dtype)
    out = constrain(out, "batch", None, "model")
    out = constrain(out @ p["wo"], "batch", None, None)
    if return_kv:
        return out, (k, v)  # post-rope K/V — exactly what the decode cache holds
    return out


# ---------------------------------------------------------------------------
# Decode-step attention against a KV cache (single token)
# ---------------------------------------------------------------------------

def attention_decode(
    p: dict,
    x: jax.Array,          # (B, 1, D)
    cache: dict,           # {'k','v': (B, Sbuf, Hkv, Dh)}
    pos: jax.Array,        # scalar int32: current absolute position
    cfg,
    *,
    window: int = 0,
    memory_kv: Optional[tuple] = None,  # precomputed cross (k, v)
):
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // hkv
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    if memory_kv is None:
        k_new = (x @ p["wk"]).reshape(b, 1, hkv, hd)
        v_new = (x @ p["wv"]).reshape(b, 1, hkv, hd)
        q = rope(q, pos[None, None], cfg.rope_theta)
        k_new = rope(k_new, pos[None, None], cfg.rope_theta)
        sbuf = cache["k"].shape[1]
        slot = pos % sbuf if window else jnp.minimum(pos, sbuf - 1)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        cache = {"k": k_cache, "v": v_cache}
        kk, vv = k_cache, v_cache
        # validity: ring buffer when windowed (all slots valid once wrapped,
        # prefix before), plain prefix when not windowed.
        idx = jnp.arange(sbuf)
        valid = idx <= jnp.minimum(pos, sbuf - 1) if window else idx <= pos
    else:
        kk, vv = memory_kv
        valid = jnp.ones((kk.shape[1],), dtype=bool)
    qg = q.reshape(b, hkv, g, hd)
    # Pin the decode contraction to the CACHE's layout (launch.steps.
    # cache_pspec: kv-heads over `model` when divisible, else head_dim):
    # left free, GSPMD re-tiles the scores dot to an (hkv x hd) split it
    # cannot reach from the cache sharding and replicates the whole cache
    # per layer (1 GiB/layer at granite-8b decode_32k — the involuntary-
    # remat warning). Pinning q (and s) to the matching sharding keeps the
    # contraction local (+ one psum of the tiny scores for the hd split).
    mesh = get_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    if msize > 1 and hkv % msize == 0:
        qg = constrain(qg, "batch", "model", None, None)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, kk,
                       preferred_element_type=jnp.float32)
        s = constrain(s, "batch", "model", None, None)
    else:
        qg = constrain(qg, "batch", None, None, "model")
        s = jnp.einsum("bhgd,bshd->bhgs", qg, kk,
                       preferred_element_type=jnp.float32)
        s = constrain(s, "batch", None, None, None)
    s = s / np.sqrt(hd)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", pr.astype(vv.dtype), vv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h * hd).astype(x.dtype)
    return constrain(o @ p["wo"], "batch", None, None), cache


def init_kv_cache(b: int, sbuf: int, hkv: int, hd: int, dtype) -> dict:
    return {
        "k": jnp.zeros((b, sbuf, hkv, hd), dtype=dtype),
        "v": jnp.zeros((b, sbuf, hkv, hd), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wgate": _dense_init(ks[0], (d, f), dtype),
        "wi": _dense_init(ks[1], (d, f), dtype),
        "w_down": _dense_init(ks[2], (f, d), dtype),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    hidden = jax.nn.silu(x @ p["wgate"]) * (x @ p["wi"])
    hidden = constrain(hidden, "batch", None, "model")
    return constrain(hidden @ p["w_down"], "batch", None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity + drop, expert parallel)
# ---------------------------------------------------------------------------

def init_moe(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.padded_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "exp_wgate": _dense_init(ks[1], (e, d, f), dtype),
        "exp_wi": _dense_init(ks[2], (e, d, f), dtype),
        "exp_w_down": _dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.moe_shared_ff:
        p["shared"] = init_mlp(ks[4], d, cfg.moe_shared_ff, dtype)
    return p


def _route(p: dict, xf: jax.Array, cfg):
    """Router: (gate (T,k), exp_ids (T,k), probs (T,E_pad))."""
    e, k = cfg.padded_experts, cfg.moe_top_k
    logits = (xf.astype(jnp.float32)) @ p["router"]          # (T, E_pad)
    if e != cfg.moe_experts:  # mask padding experts out of routing
        logits = jnp.where(jnp.arange(e) >= cfg.moe_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, exp_ids = jax.lax.top_k(probs, k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, exp_ids, probs


def _aux_loss(probs: jax.Array, exp_ids: jax.Array, e: int) -> jax.Array:
    """Switch-style load-balance loss over the given token set."""
    density = jnp.mean(jax.nn.one_hot(exp_ids[:, 0], e), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    return e * jnp.sum(density * router_mean)


def _capacity(cfg, t: int, e: int) -> int:
    cap = int(np.ceil(cfg.moe_capacity_factor * t * cfg.moe_top_k / e))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU friendliness


def _dispatch_ffn(xf, gate, exp_ids, wgate, wi, wdown, cap: int):
    """Sort-based capacity dispatch + expert FFN + combine, over the experts
    present in ``wgate`` (E_loc). ``exp_ids`` entries outside [0, E_loc) are
    treated as not-mine (the expert-parallel path remaps and masks before
    calling). Returns (T, d) partial output (zeros for foreign tokens).
    """
    t, d = xf.shape
    e_loc = wgate.shape[0]
    k = exp_ids.shape[1]
    flat_exp = jnp.clip(exp_ids.reshape(-1), -1, e_loc)       # (T*k,)
    mine = (flat_exp >= 0) & (flat_exp < e_loc)
    sort_key = jnp.where(mine, flat_exp, e_loc)
    order = jnp.argsort(sort_key, stable=True)
    sorted_exp = sort_key[order]
    sorted_tok = order // k
    sorted_gate = gate.reshape(-1)[order]
    counts = jnp.bincount(sort_key, length=e_loc + 1)[:e_loc]
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    pos_in_exp = jnp.arange(t * k) - starts[jnp.clip(sorted_exp, 0, e_loc - 1)]
    keep = (sorted_exp < e_loc) & (pos_in_exp < cap)
    n_slots = e_loc * cap
    slot = jnp.where(keep, sorted_exp * cap + pos_in_exp, n_slots)

    # Invert the token->slot map and index PER SLOT: gathering xf by
    # sorted_tok first would materialize a (T*k, d) tensor (4 GiB/device at
    # jamba scale); the slot-indexed view touches only (E_loc*cap, d).
    tok_for_slot = jnp.zeros(n_slots + 1, jnp.int32).at[slot].set(sorted_tok)
    gate_for_slot = jnp.zeros(n_slots + 1, sorted_gate.dtype).at[slot].set(sorted_gate)
    valid_slot = jnp.zeros(n_slots + 1, bool).at[slot].set(keep)
    tok_idx = tok_for_slot[:n_slots]
    slot_gate = (gate_for_slot[:n_slots] * valid_slot[:n_slots])

    buf = jnp.where(valid_slot[:n_slots, None], xf[tok_idx], 0)
    buf = buf.reshape(e_loc, cap, d)
    hidden = jnp.einsum("ecd,edf->ecf", buf, wgate)
    hidden = jax.nn.silu(hidden) * jnp.einsum("ecd,edf->ecf", buf, wi)
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, wdown).reshape(n_slots, d)
    contrib = out_buf * slot_gate[:, None].astype(xf.dtype)
    return jnp.zeros((t, d), xf.dtype).at[tok_idx].add(contrib)


def moe(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Returns (output, load-balance aux loss).

    Two execution paths with identical math (tested against each other):

    * no mesh (CPU smoke): single-device sort-based capacity dispatch.
    * mesh installed: **expert-parallel shard_map** — tokens stay on their
      data shard (the global GSPMD sort would all-gather every token);
      each model rank routes all of its local tokens but runs the FFN only
      for its E/M local experts, then one psum over ``model`` combines
      expert contributions — the same single-collective profile as a dense
      TP MLP. Capacity is per (data-shard x expert), the standard
      data-parallel Switch semantics.
    """
    from . import sharding as _sh

    b, s, d = x.shape
    e = cfg.padded_experts
    mesh = _sh.get_mesh()
    use_ep = (
        mesh is not None
        and "model" in mesh.axis_names
        and e % mesh.shape["model"] == 0
    )

    if not use_ep:
        xf = x.reshape(b * s, d)
        gate, exp_ids, probs = _route(p, xf, cfg)
        aux = _aux_loss(probs, exp_ids, e)
        cap = _capacity(cfg, b * s, e)
        out = _dispatch_ffn(
            xf, gate, exp_ids, p["exp_wgate"], p["exp_wi"], p["exp_w_down"], cap
        ).reshape(b, s, d)
    else:
        out, aux = _moe_expert_parallel(p, x, cfg, mesh)
    if "shared" in p:
        out = out + mlp(p["shared"], x)
    return constrain(out, "batch", None, None), aux


def _moe_expert_parallel(p, x, cfg, mesh):
    from . import sharding as _sh

    e = cfg.padded_experts
    msize = mesh.shape["model"]
    e_loc = e // msize
    b = x.shape[0]
    # batch axes that divide b (long-context decode has b=1: replicate)
    baxes = tuple(
        a for a in ("pod", "data") if a in mesh.axis_names
    )
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    if b % max(bsize, 1) != 0:
        baxes, bsize = (), 1
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    data_axes = baxes  # aux-loss mean over these

    f = cfg.d_ff
    dsize = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dsize *= mesh.shape[a]
    if _sh.get_ep2d() and dsize > 1 and f % dsize == 0:
        return _moe_ep2d(p, x, cfg, mesh, e, e_loc, bspec, baxes, bsize)

    # Expert weights are stored FSDP-sharded over `data` (training). The
    # shard_map in_specs MATCH that layout and the un-FSDP all-gather is
    # issued EXPLICITLY inside the body: letting shard_map reshard to a
    # data-replicated spec instead makes GSPMD materialize the full
    # (E, d, f) tensor on the multi-pod mesh (12 GiB f32 per copy at jamba
    # scale — the same device-order "last resort" replication as the embed
    # gather). f divisibility decides whether the stored layout is f-over-
    # data; fall back to replicated specs otherwise (small experts).
    dsize2 = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dsize2 *= mesh.shape[a]
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    f_sharded = bool(daxes) and cfg.d_ff % dsize2 == 0

    def body(x_loc, router, wg, wi, wd):
        bl, sl, d = x_loc.shape
        if f_sharded:  # un-FSDP the expert shards for this step's compute
            wg = jax.lax.all_gather(wg, daxes, axis=2, tiled=True)
            wi = jax.lax.all_gather(wi, daxes, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, daxes, axis=1, tiled=True)
        xf = x_loc.reshape(bl * sl, d)
        gate, exp_ids, probs = _route({"router": router}, xf, cfg)
        aux = _aux_loss(probs, exp_ids, e)
        if data_axes:
            aux = jax.lax.pmean(aux, data_axes)
        midx = jax.lax.axis_index("model")
        local_ids = exp_ids - midx * e_loc   # out-of-range => masked in dispatch
        cap = _capacity(cfg, bl * sl, e)
        part = _dispatch_ffn(xf, gate, local_ids, wg, wi, wd, cap)
        out = jax.lax.psum(part, "model")
        return out.reshape(bl, sl, d), aux

    P = jax.sharding.PartitionSpec
    if f_sharded:
        w_specs = (P("model", None, "data"), P("model", None, "data"),
                   P("model", "data", None))
    else:
        w_specs = (P("model", None, None), P("model", None, None),
                   P("model", None, None))
    out, aux = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None)) + w_specs,
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["exp_wgate"], p["exp_wi"], p["exp_w_down"])
    return out, aux


def _moe_ep2d(p, x, cfg, mesh, e, e_loc, bspec, data_axes, dsize):
    """Decode-serving MoE for experts too big for model-TP alone.

    Weights: experts over `model`, d_ff over `data` (2D) — fully resident,
    never gathered. Activations move instead: the (tiny) decode token set
    is all-gathered over `data`, every device runs routing + its expert's
    FFN on its d_ff slice, and ONE psum over (model, data) sums both the
    expert contributions and the d_ff partial products. Per MoE layer the
    wire cost is O(T*d) (~MB at decode batch sizes) instead of O(E_loc *
    d * d_ff) weight gathers (~GB): the weight-stationary inversion.
    The d_ff nonlinearity is elementwise, so f-slices compose exactly.
    """

    def body(x_loc, router, wg, wi, wd):
        bl, sl, dm = x_loc.shape
        x_all = jax.lax.all_gather(x_loc, data_axes, axis=0, tiled=True)
        xf = x_all.reshape(-1, dm)
        gate, exp_ids, probs = _route({"router": router}, xf, cfg)
        aux = _aux_loss(probs, exp_ids, e)  # identical on all ranks
        midx = jax.lax.axis_index("model")
        local_ids = exp_ids - midx * e_loc
        cap = _capacity(cfg, xf.shape[0], e)
        part = _dispatch_ffn(xf, gate, local_ids, wg, wi, wd, cap)
        out = jax.lax.psum(part, ("model",) + tuple(data_axes))
        out = out.reshape(bl * dsize, sl, dm)
        didx = jax.lax.axis_index(data_axes)
        out_loc = jax.lax.dynamic_slice_in_dim(out, didx * bl, bl, axis=0)
        return out_loc, aux

    P = jax.sharding.PartitionSpec
    out, aux = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P("model", None, "data"),
            P("model", None, "data"),
            P("model", "data", None),
        ),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["exp_wgate"], p["exp_wi"], p["exp_w_down"])
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state space duality, arXiv:2405.21060) chunked scan
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype) -> dict:
    d, di, n, hd_s = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 5)
    return {
        # projects to [z (di), x (di), B (n), C (n), dt (nh)]
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * n + nh), dtype),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), dtype, scale=3.0),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ssm_d": jnp.ones((nh,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
        "norm_scale": jnp.ones((di,), dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W: xbc (B, S, C), w (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + b[None, None, :])


def _ssd_scan(xh, dt, a_log, bmat, cmat, chunk: int):
    """Chunked SSD (state-space duality, arXiv:2405.21060 §6).

    xh (B,S,H,P) f32, dt (B,S,H) post-softplus, B/C (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Structured as ONE sequential ``lax.scan`` over chunks carrying the
    (B,H,P,N) state; each step processes every head at once:
      * intra-chunk: the masked "attention" form — scores C_i.B_j are shared
        across heads, scaled by the per-head decay exp(cum_i - cum_j);
      * inter-chunk: contract the carried state against C and the decay.
    The decay mask is applied to the EXPONENT (where -> exp), not the value:
    exp of a positive masked slot would be inf and inf*0 NaNs the backward.
    Peak per-step memory is the (B,l,l,H) decay — ``_ssd_sizes`` picks l.
    """
    b, s, h, p_dim = xh.shape
    n = bmat.shape[-1]
    l = largest_divisor(s, chunk)
    nc = s // l
    mask = jnp.tril(jnp.ones((l, l), bool))  # i >= j

    a = -jnp.exp(a_log)[None, None, :]                       # (1,1,H)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, nc, l, *t.shape[2:]), 1, 0)

    xs = (to_chunks(xh), to_chunks(dt), to_chunks(bmat), to_chunks(cmat))

    def chunk_step(hstate, inp):
        xcc, dtcc, bcc, ccc = inp                            # (B,l,H,P) ...
        la = a * dtcc                                        # (B,l,H), <= 0
        cum = jnp.cumsum(la, axis=1)                         # (B,l,H)
        scores = jnp.einsum("bin,bjn->bij", ccc, bcc)        # head-shared
        diff = cum[:, :, None, :] - cum[:, None, :, :]       # (B,l,l,H) i,j
        diff = jnp.where(mask[None, :, :, None], diff, -jnp.inf)
        decay = jnp.exp(diff)                                # masked slots -> 0
        w = scores[:, :, :, None] * decay                    # (B,l,l,H)
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", w, dtcc, xcc)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", ccc, hstate, jnp.exp(cum))
        seg = jnp.exp(cum[:, -1:, :] - cum)                  # (B,l,H)
        state_c = jnp.einsum("bjh,bjn,bjhp->bhpn", seg * dtcc, bcc, xcc)
        hnew = hstate * jnp.exp(cum[:, -1, :])[:, :, None, None] + state_c
        return hnew, y_intra + y_inter

    init = jnp.zeros((b, h, p_dim, n), jnp.float32)
    h_final, y = jax.lax.scan(chunk_step, init, xs)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, p_dim)
    return y, h_final


def _ssd_sizes(b: int, s: int, h: int, budget_bytes: int = 4 * 2**30):
    """Chunk length l so the intra-chunk decay tensor B*l*l*H*4 stays under
    ``budget_bytes`` GLOBALLY (so ~budget/16 per data shard) — jamba-scale
    d_inner would otherwise materialize multi-GB decays per scan step."""
    for l in (256, 128, 64, 32):
        if b * l * l * h * 4 <= budget_bytes:
            return l
    return 16


def mamba(p: dict, x: jax.Array, cfg, *, chunk: int = 0, return_cache: bool = False):
    b, s, d = x.shape
    di, n, nh, hd_s = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    proj = constrain(proj, "batch", None, "model")
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    xbc_raw = jnp.concatenate([xin, bmat, cmat], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xin.reshape(b, s, nh, hd_s).astype(jnp.float32)
    auto_chunk = _ssd_sizes(b, s, nh)
    y, h_final = _ssd_scan(
        xh, dt, p["a_log"], bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        chunk or auto_chunk,
    )
    y = y + p["ssm_d"][None, None, :, None] * xh
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)  # gated
    y = rmsnorm({"scale": p["norm_scale"]}, y)
    out = constrain(y @ p["out_proj"], "batch", None, None)
    if return_cache:
        w = cfg.ssm_conv_width
        # decode expects the raw (pre-conv) last W-1 inputs
        conv_cache = xbc_raw[:, -(w - 1):, :] if s >= w - 1 else jnp.pad(
            xbc_raw, ((0, 0), (w - 1 - s, 0), (0, 0))
        )
        return out, {"conv": conv_cache, "ssm": h_final}
    return out


def mamba_decode(p: dict, x: jax.Array, cache: dict, cfg):
    """Single-token SSD step. cache: {'conv': (B, W-1, C), 'ssm': (B,H,P,N)}."""
    b, _, d = x.shape
    di, n, nh, hd_s = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    xbc = jnp.concatenate([xin, bmat, cmat], axis=-1)  # (B,1,C)
    conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,C)
    conv_out = jnp.sum(conv_in * p["conv_w"][None], axis=1, keepdims=True)
    xbc = jax.nn.silu(conv_out + p["conv_b"][None, None, :])
    new_conv = conv_in[:, 1:, :]
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt)  # (B,H)
    xh = xin.reshape(b, nh, hd_s).astype(jnp.float32)
    bm = bmat[:, 0].astype(jnp.float32)  # (B,N)
    cm = cmat[:, 0].astype(jnp.float32)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt, bm, xh)
    hstate = cache["ssm"] * a[:, :, None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", cm, hstate) + p["ssm_d"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm_scale"]}, y)
    out = constrain(y @ p["out_proj"], "batch", None, None)
    return out, {"conv": new_conv, "ssm": hstate}


def init_mamba_cache(b: int, cfg, dtype) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((b, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }
