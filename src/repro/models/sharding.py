"""Sharding rules: parameter PartitionSpecs + activation constraints.

Global-view GSPMD style (the MaxText pattern): model code is written on
global shapes and annotated with ``with_sharding_constraint``; the mesh is
installed process-wide by the launcher via :func:`set_mesh`. When no mesh is
set (CPU smoke tests) all constraints are no-ops, so the same model code
runs on 1 device and on the 512-chip production mesh.

Axes:
  * ``model`` — tensor parallel (attention heads / ffn hidden / experts /
    vocab) — the vertical axis, mirroring the paper's feature partition.
  * ``data``  — batch + FSDP shard of the weights.
  * ``pod``   — outer data axis (pure DP between pods) on the multi-pod mesh.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_EP2D: bool = False


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def set_ep2d(on: bool) -> None:
    """2D expert sharding for decode of models whose experts don't fit
    model-TP (jamba-398B): experts over `model`, d_ff over `data`; the MoE
    layer then moves ACTIVATIONS (all-gather the handful of decode tokens)
    instead of gathering GBs of expert weights per token (see
    layers._moe_expert_parallel and EXPERIMENTS.md §Perf H2)."""
    global _EP2D
    _EP2D = on


def get_ep2d() -> bool:
    return _EP2D


def get_mesh() -> Optional[Mesh]:
    return _MESH


def batch_axes():
    """Mesh axes a global batch dim is sharded over."""
    if _MESH is None:
        return None
    names = _MESH.axis_names
    return tuple(a for a in ("pod", "data") if a in names) or None


def fsdp_axis():
    if _MESH is None:
        return None
    return "data" if "data" in _MESH.axis_names else None


def constrain(x: jax.Array, *spec):
    """with_sharding_constraint if a mesh is installed, else identity.

    ``spec`` entries: None, axis name, tuple of axis names, or the sentinel
    'batch' which expands to the (pod, data) batch axes. Axes that do not
    divide the corresponding dim are dropped (a constraint that forces
    padding triggers involuntary full rematerialization in SPMD).
    """
    if _MESH is None:
        return x
    spec = tuple(batch_axes() if s == "batch" else s for s in spec)
    fixed = []
    for dim, s in zip(x.shape, spec):
        axes = s if isinstance(s, tuple) else (s,) if s else ()
        size = 1
        for a in axes:
            size *= _MESH.shape[a]
        fixed.append(s if dim % max(size, 1) == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter partition rules, keyed on the trailing path element (leaf name).
# Specs are for the UNSTACKED parameter; scan-stacked leaves (ndim = rule
# ndim + 1) get a leading None automatically.
# ---------------------------------------------------------------------------

_RULES: list[tuple[str, tuple]] = [
    # name-regex, spec for trailing dims (fsdp added separately)
    (r"embed$", ("model", None)),  # vocab over TP: GSPMD lowers the token
                                   # gather as local-shard gather + mask +
                                   # all-reduce (d-sharded tables rematerialize)
    (r"unembed$", (None, "model")),
    (r"w(q|k|v)$", (None, "model")),
    (r"wo$", ("model", None)),
    (r"w(i|gate)$", (None, "model")),
    (r"w_down$", ("model", None)),
    (r"router$", (None, None)),
    (r"exp_w(i|gate)$", ("model", None, None)),     # expert parallel
    (r"exp_w_down$", ("model", None, None)),
    (r"in_proj$", (None, "model")),
    (r"out_proj$", ("model", None)),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"(a_log|dt_bias|ssm_d)$", ("model",)),
    (r"(scale|bias)$", (None,)),
    (r"pos_embed$", (None, None)),
]


def spec_for(path: str, ndim: int, *, fsdp: bool = True) -> P:
    name = path.split("/")[-1]
    for pat, spec in _RULES:
        if re.search(pat, name):
            spec = list(spec)
            if fsdp and len(spec) >= 2:
                # FSDP: shard one replicated dim over data. Prefer dim 0.
                for i, s in enumerate(spec):
                    if s is None:
                        spec[i] = "data"
                        break
            while len(spec) < ndim:
                spec.insert(0, None)  # scan-stacked leading dim(s)
            if len(spec) != ndim:
                spec = [None] * (ndim - len(spec)) + list(spec)[-ndim:]
            return P(*spec)
    return P(*([None] * ndim))


_EP2D_RULES = {
    "exp_wgate": ("model", None, "data"),   # (E, d, f): f over data
    "exp_wi": ("model", None, "data"),
    "exp_w_down": ("model", "data", None),  # (E, f, d)
}


def param_specs(params, *, fsdp: bool = True, expert_data: bool = False):
    """PartitionSpec pytree matching ``params`` (by leaf path rules).

    ``expert_data``: override the expert-weight rules with the 2D layout
    (experts over model, d_ff over data) — decode-serving of MoE models
    too big for model-TP alone."""

    def one(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        name = str(keys[-1]) if keys else ""
        if expert_data and name in _EP2D_RULES:
            spec = list(_EP2D_RULES[name])
            while len(spec) < leaf.ndim:
                spec.insert(0, None)
            return P(*spec)
        return spec_for("/".join(str(k) for k in keys), leaf.ndim, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(one, params)


def _drop_indivisible(mesh: Mesh, spec: P, shape: tuple) -> P:
    """Drop mesh axes whose size does not divide the corresponding dim —
    jit in/out shardings require exact divisibility (unlike constraints)."""
    fixed = []
    for dim, s in zip(shape, spec):
        axes = s if isinstance(s, tuple) else (s,) if s else ()
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(s if size and dim % max(size, 1) == 0 else None)
    return P(*fixed)


def constrain_tree(tree, *, fsdp: bool = True):
    """with_sharding_constraint a param-shaped pytree to the rule-derived
    specs (no-op when no mesh is installed). Used to pin gradient
    accumulators to the same layout as the params they mirror."""
    if _MESH is None:
        return tree
    specs = param_specs(tree, fsdp=fsdp)
    return jax.tree.map(
        lambda leaf, spec: jax.lax.with_sharding_constraint(
            leaf,
            NamedSharding(_MESH, _drop_indivisible(_MESH, spec, leaf.shape)),
        ),
        tree,
        specs,
    )


def param_shardings(mesh: Mesh, params, *, fsdp: bool = True,
                    expert_data: bool = False):
    specs = param_specs(params, fsdp=fsdp, expert_data=expert_data)
    return jax.tree.map(
        lambda s, leaf: NamedSharding(mesh, _drop_indivisible(mesh, s, leaf.shape)),
        specs,
        params,
    )
